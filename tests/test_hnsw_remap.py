"""HNSW external-id -> dense-internal-slot remapping: arbitrary 64-bit
ids must not balloon the vector array / pickles, and freed slots are
recycled. (Kept hypothesis-free so it collects everywhere; structural
property tests live in test_hnsw.py.)"""
import pickle

import numpy as np

from repro.core.hnsw import HNSW


def build(n=60, d=12, seed=0, ids=None):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    g = HNSW(d, M=8, ef_construction=32, seed=seed, max_elements=8)
    ids = range(n) if ids is None else ids
    for i, vid in enumerate(ids):
        g.insert(int(vid), X[i])
    return g, X


def test_huge_ids_stay_dense():
    n = 60
    base = 10**15
    g, X = build(n, ids=[base + 7 * i for i in range(n)])
    # the vectors array scales with the node count, not the id magnitude
    assert g.vectors.shape[0] <= 4 * n
    ids, _ = g.search(X[3], k=1, ef_search=64)
    assert int(ids[0]) == base + 21


def test_pickle_size_independent_of_id_magnitude():
    g_small, _ = build(40, ids=range(40))
    g_huge, _ = build(40, ids=[10**12 + i for i in range(40)])
    s, h = len(pickle.dumps(g_small)), len(pickle.dumps(g_huge))
    assert h < 2 * s


def test_graph_arrays_returns_external_ids():
    base = 5_000_000
    g, X = build(20, ids=[base + i for i in range(20)])
    ids, vecs = g.graph_arrays()
    assert set(map(int, ids)) == {base + i for i in range(20)}
    assert vecs.shape == (20, 12)
    # exported vectors line up with their external ids
    for vid, v in zip(ids, vecs):
        np.testing.assert_array_equal(v, X[int(vid) - base])


def test_delete_recycles_slots():
    g, X = build(30)
    cap0 = g.vectors.shape[0]
    for round_ in range(5):
        vid = 10**9 + round_
        g.insert(vid, X[0] + 0.01 * round_)
        g.delete(vid)
    assert g.vectors.shape[0] == cap0       # churn reused freed slots
    ids, _ = g.search(X[1], k=1, ef_search=64)
    assert int(ids[0]) == 1


def test_reinsert_same_external_id():
    g, X = build(20)
    g.delete(5)
    g.insert(5, X[5])
    ids, _ = g.search(X[5], k=1, ef_search=64)
    assert int(ids[0]) == 5


def test_recycled_slot_reuse_under_heavy_churn():
    """ROADMAP debt from PR 1/2: interleaved delete/re-add cycles must
    keep recycling freed slots (internal slot count bounded by the peak
    live count, backing array never regrows) and search must stay correct
    on the survivors and the re-added points."""
    n, d = 48, 12
    g, X = build(n, d=d)
    peak_live = len(g)
    cap0 = g.vectors.shape[0]
    rng = np.random.default_rng(3)
    extra = rng.normal(size=(200, d)).astype(np.float32)
    next_id = 10**9
    live = {i: X[i] for i in range(n)}
    for cycle in range(8):
        # delete a third of the live set...
        doomed = rng.choice(sorted(live), size=len(live) // 3, replace=False)
        for vid in doomed:
            g.delete(int(vid))
            live.pop(int(vid))
        # ...and re-add the same number under fresh (huge) external ids
        for _ in range(len(doomed)):
            vec = extra[(next_id - 10**9) % len(extra)]
            g.insert(next_id, vec)
            live[next_id] = vec
            next_id += 1
        assert len(g) == peak_live == len(live)
        # slot count stays <= peak live ids: churn reuses freed slots
        assert len(g._int2ext) <= peak_live
        assert g.vectors.shape[0] == cap0
    # search correctness after churn: every probe's exact point comes back
    hits = 0
    probes = rng.choice(sorted(live), size=12, replace=False)
    for vid in probes:
        ids, _ = g.search(live[int(vid)], k=1, ef_search=96)
        hits += int(ids[0]) == int(vid)
    assert hits >= 10  # graph quality survives heavy delete/re-add churn


def test_reconstruct_by_external_id():
    base = 77_000_000
    g, X = build(10, ids=[base + i for i in range(10)])
    np.testing.assert_array_equal(g.reconstruct(base + 4), X[4])
