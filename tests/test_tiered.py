"""Tiered hot/cold EcoVector (DESIGN.md §14): bit-identical results at
every device budget, promotion/demotion under churn without leaking
device rows or exceeding the budget, crash recovery mid-demotion via the
store fault hooks, cold-pack corruption healing/quarantine, and the new
memory-accounting surfaces (ram_bytes, WindowIndex resident/DMA)."""
import os
import warnings
import shutil

import numpy as np
import pytest

from repro.core import store, store_faults
from repro.core.ecovector import EcoVector
from repro.core.scr import SCRConfig, apply_scr_batch
from repro.core.tiered import (ColdPack, TieredEcoVector, TierManager,
                               scrub_cold_pack, scrub_tier_state)
from repro.core.window_index import WindowIndex
from repro.kernels import ref
from repro.kernels.ecoscan import ecoscan
from repro.serving.embedder import HashEmbedder

DIM = 16


@pytest.fixture(autouse=True)
def _clean_hooks():
    store.set_crash_hook(None)
    store.reset_fs_ops()
    yield
    store.set_crash_hook(None)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(11)
    centers = rng.normal(scale=4.0, size=(8, DIM))
    X = (centers.repeat(40, axis=0)
         + rng.normal(size=(320, DIM))).astype(np.float32)
    Q = (X[rng.choice(len(X), 12)]
         + 0.05 * rng.normal(size=(12, DIM))).astype(np.float32)
    return X, Q


def _base(X, **kw):
    kw.setdefault("n_clusters", 8)
    kw.setdefault("M", 8)
    kw.setdefault("ef_construction", 32)
    return EcoVector(DIM, **kw).build(X)


def _tiered(X, tmp_path=None, **kw):
    kw.setdefault("n_clusters", 8)
    kw.setdefault("M", 8)
    kw.setdefault("ef_construction", 32)
    if tmp_path is not None:
        kw.setdefault("storage_dir", str(tmp_path))
    return TieredEcoVector(DIM, **kw).build(X)


def _no_leaks(tv):
    """Structural tier invariants: every device row is either owned by
    exactly one hot cluster or on the free list; hot/cold are disjoint
    and with quarantined cover every cluster."""
    occupied = {r for r, c in enumerate(tv._row_cluster) if c >= 0}
    free = set(tv._free_rows)
    assert not (occupied & free)
    assert occupied | free == set(range(len(tv._row_cluster)))
    hot, cold = tv.hot_clusters(), tv.cold_clusters()
    assert not (hot & cold)
    assert hot | cold | tv._quarantined == set(range(tv.n_clusters))
    if tv.device_budget_bytes is not None:
        # routing centroids are a fixed floor even when the budget is
        # set below them (the all-cold degenerate case warns instead)
        assert (tv.device_resident_bytes()
                <= max(tv.device_budget_bytes, tv._fixed_device_bytes()))


# ----------------------------------------------------- kernel block_map

@pytest.mark.parametrize("use_pallas", [True, False])
def test_ecoscan_block_map_matches_identity(use_pallas):
    """A permuted scan layout with a block_map must yield bitwise the
    same results (after id remap) as the identity layout — both in the
    interpret-mode Pallas kernel and the numpy reference."""
    rng = np.random.default_rng(0)
    NC, CAP, d, B, P, K = 6, 8, 16, 3, 4, 5
    data = rng.normal(size=(NC, CAP, d)).astype(np.float32)
    lens = rng.integers(1, CAP + 1, NC).astype(np.int32)
    q = rng.normal(size=(B, d)).astype(np.float32)
    probes = rng.integers(0, NC, (B, P)).astype(np.int32)
    probes[0, -1] = -1                                  # padded probe

    fn = ecoscan if use_pallas else ref.ecoscan
    d_id, i_id = fn(q, data, lens, probes, k=K)

    perm = rng.permutation(NC).astype(np.int32)          # cluster -> row
    d_perm, i_perm = fn(q, data[np.argsort(perm)][..., :, :],
                        lens[np.argsort(perm)], probes, k=K,
                        block_map=perm)
    np.testing.assert_array_equal(np.asarray(d_id), np.asarray(d_perm))
    ii, ip = np.asarray(i_id), np.asarray(i_perm)
    # identity ids are c*CAP+s; permuted ids are perm[c]*CAP+s
    remap = np.where(ip >= 0, np.argsort(perm)[np.clip(ip, 0, None)
                                               // CAP] * CAP + ip % CAP, -1)
    np.testing.assert_array_equal(ii, remap)


def test_ecoscan_block_map_masks_clusters():
    """block_map entries < 0 hide a cluster: none of its slots appear."""
    rng = np.random.default_rng(1)
    NC, CAP, d = 4, 8, 16
    data = rng.normal(size=(NC, CAP, d)).astype(np.float32)
    lens = np.full(NC, CAP, np.int32)
    q = rng.normal(size=(2, d)).astype(np.float32)
    probes = np.tile(np.arange(NC, dtype=np.int32), (2, 1))
    bmap = np.arange(NC, dtype=np.int32)
    bmap[2] = -1
    for fn in (ecoscan, ref.ecoscan):
        _, ids = fn(q, data, lens, probes, k=NC * CAP, block_map=bmap)
        ids = np.asarray(ids)
        hidden = (ids >= 2 * CAP) & (ids < 3 * CAP)
        assert not hidden.any()


# ------------------------------------------------------------ parity

def test_bit_identical_across_budgets(tmp_path, data):
    """The tentpole guarantee: ids AND dists from the tiered index are
    bitwise equal to the all-resident base index at equal n_probe, at
    100% hot, mixed splits, and all-cold."""
    X, Q = data
    base = _base(X)
    ref_ids, ref_d = base.search_device_batched(Q, k=10, n_probe=4,
                                                use_pallas=False)
    tv = _tiered(X, tmp_path / "t")
    full = tv.all_resident_bytes()
    for frac in (None, 1.0, 0.5, 0.25, 0.02):
        with warnings.catch_warnings():
            # the tiniest budget may dip under the centroid floor, which
            # legitimately warns "serving all-cold"
            warnings.simplefilter("ignore", UserWarning)
            tv.set_device_budget(None if frac is None else int(frac * full))
            ids, d = tv.search_device_batched(Q, k=10, n_probe=4,
                                              use_pallas=False)
        np.testing.assert_array_equal(ids, np.asarray(ref_ids))
        np.testing.assert_array_equal(np.asarray(d), np.asarray(ref_d))
        _no_leaks(tv)
    assert tv.cold_clusters()                   # the tiny budget went cold
    assert tv.stats.tier_cold_hits > 0


def test_parity_holds_while_tiers_move(tmp_path, data):
    """Repeated skewed batches move the EMA (promotions/demotions fire)
    and every single batch stays bit-identical to the base index."""
    X, Q = data
    base = _base(X)
    tv = _tiered(X, tmp_path / "t")
    tv.set_device_budget(int(0.5 * tv.all_resident_bytes()))
    rng = np.random.default_rng(2)
    for it in range(6):
        batch = Q if it % 2 == 0 else np.repeat(Q[it % len(Q)][None], 4, 0)
        bi, bd = base.search_device_batched(batch, k=8, n_probe=3,
                                            use_pallas=False)
        ti, td = tv.search_device_batched(batch, k=8, n_probe=3,
                                          use_pallas=False)
        np.testing.assert_array_equal(ti, np.asarray(bi))
        np.testing.assert_array_equal(np.asarray(td), np.asarray(bd))
        _no_leaks(tv)
    assert tv.stats.promotions + tv.stats.demotions > 0
    hits = tv.stats.tier_hot_hits + tv.stats.tier_cold_hits
    assert hits > 0 and tv.stats.tier_hot_hits > 0


# ------------------------------------------------------ churn / budget

def test_churn_never_exceeds_budget_or_leaks_rows(tmp_path, data):
    X, Q = data
    rng = np.random.default_rng(3)
    tv = _tiered(X, tmp_path / "t")
    budget = int(0.4 * tv.all_resident_bytes())
    tv.set_device_budget(budget)
    base_vid = 10 ** 6
    for cycle in range(4):
        for i in range(5):
            tv.insert(base_vid + 5 * cycle + i,
                      rng.normal(size=DIM).astype(np.float32))
        tv.delete(base_vid + 5 * cycle)
        ids, _ = tv.search_device_batched(Q[:4], k=8, n_probe=4,
                                          use_pallas=False)
        assert ids.shape == (4, 8)
        _no_leaks(tv)
        assert tv.device_resident_bytes() <= budget


def test_cold_insert_marks_dirty_without_promotion(tmp_path, data):
    """Inserting into a cold cluster updates the cold pack in place at
    the next sync — it does not force the cluster hot."""
    X, Q = data
    tv = _tiered(X, tmp_path / "t")
    tv.set_device_budget(int(0.4 * tv.all_resident_bytes()))
    tv.search_device_batched(Q[:2], k=5, n_probe=2, use_pallas=False)
    cold = sorted(tv.cold_clusters())
    assert cold
    c = cold[0]
    vid = 7 * 10 ** 6
    # a point at the centroid is guaranteed to route to cluster c
    tv.insert(vid, tv.centroids[c].astype(np.float32))
    assert tv.assign[vid] == c
    assert c in tv._dirty
    tv._tier_sync(moves=0)
    assert c in tv.cold_clusters() and c not in tv.hot_clusters()
    ids, _ = tv._cold.get(c)
    assert vid in set(map(int, ids))


def test_budget_smaller_than_centroids_serves_all_cold(tmp_path, data):
    X, Q = data
    tv = _tiered(X, tmp_path / "t")
    base = _base(X)
    with pytest.warns(UserWarning, match="serving all-cold"):
        tv.set_device_budget(8)
        ids, d = tv.search_device_batched(Q[:4], k=10, n_probe=4,
                                          use_pallas=False)
    bi, bd = base.search_device_batched(Q[:4], k=10, n_probe=4,
                                        use_pallas=False)
    np.testing.assert_array_equal(ids, np.asarray(bi))
    assert not tv.hot_clusters()


def test_device_pack_is_refused(tmp_path, data):
    X, _ = data
    tv = _tiered(X, tmp_path / "t")
    with pytest.raises(store.StoreError):
        tv.device_pack()


# ------------------------------------------------------- persistence

def test_save_load_restores_tiers_and_budget(tmp_path, data):
    X, Q = data
    tv = _tiered(X, tmp_path / "spill")
    tv.set_device_budget(int(0.5 * tv.all_resident_bytes()))
    ref_ids, ref_d = tv.search_device_batched(Q, k=10, n_probe=4,
                                              use_pallas=False)
    root = str(tmp_path / "j")
    tv.save(root)
    tv2 = TieredEcoVector.load(root)
    assert tv2.device_budget_bytes == tv.device_budget_bytes
    tv2._activate()                 # before any search moves tiers
    assert tv2.hot_clusters() == tv.hot_clusters()
    ids, d = tv2.search_device_batched(Q, k=10, n_probe=4,
                                       use_pallas=False)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(ref_d))
    # WAL replay path: a post-save insert survives reload and reaches
    # its tier on the next search
    vid = 9 * 10 ** 6
    tv2.insert(vid, X[0])
    tv3 = TieredEcoVector.load(root)
    assert tv3.stats.wal_replayed >= 1
    assert vid in tv3.assign
    i3, _ = tv3.search_device_batched(X[0][None], k=5, n_probe=4,
                                      use_pallas=False)
    assert vid in set(map(int, i3[0]))
    _no_leaks(tv3)


def test_kill9_sweep_mid_demotion_and_save(tmp_path, data):
    """Crash at every Nth fs op while (a) shrinking the budget — the
    demotion write-through path — and (b) saving the tiered snapshot.
    Reload must always give a complete index, bit-identical to the
    uncrashed reference, with a clean tier scrub."""
    X, Q = data
    tv = _tiered(X, tmp_path / "spill")
    tv.set_device_budget(int(0.8 * tv.all_resident_bytes()))
    tv.search_device_batched(Q, k=10, n_probe=4, use_pallas=False)
    base_root = str(tmp_path / "base")
    tv.save(base_root)
    shrink = int(0.3 * tv.all_resident_bytes())

    def crashable(idx, root):
        idx.set_device_budget(shrink)     # demotions write through
        idx.search_device_batched(Q[:2], k=5, n_probe=4,
                                  use_pallas=False)
        idx.save(root)

    # reference: the same workload, no crash
    ref_root = str(tmp_path / "ref")
    shutil.copytree(base_root, ref_root)
    ref_idx = TieredEcoVector.load(ref_root)
    crashable(ref_idx, ref_root)
    ref_ids, ref_d = ref_idx.search_device_batched(Q, k=10, n_probe=4,
                                                   use_pallas=False)

    probe_root = str(tmp_path / "probe_cp")
    shutil.copytree(base_root, probe_root)
    probe_idx = TieredEcoVector.load(probe_root)
    total = store_faults.count_fs_ops(
        lambda: crashable(probe_idx, probe_root))
    assert total >= 8
    for at in range(1, total + 1, 3):
        root = str(tmp_path / f"r{at}")
        shutil.copytree(base_root, root)
        idx = TieredEcoVector.load(root)
        with store_faults.CrashPlan(at) as plan:
            try:
                crashable(idx, root)
            except store_faults.InjectedCrash:
                pass
        idx2 = TieredEcoVector.load(root)
        ids, d = idx2.search_device_batched(Q, k=10, n_probe=4,
                                            use_pallas=False)
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(ref_d))
        _no_leaks(idx2)
        assert all(r["ok"] for r in scrub_tier_state(root)), at


# ------------------------------------------------------- corruption

def _force_all_synced(tv, Q):
    tv.search_device_batched(Q[:2], k=5, n_probe=4, use_pallas=False)


def test_cold_corruption_heals_from_spill(tmp_path, data):
    X, Q = data
    tv = _tiered(X, tmp_path / "t")
    tv.set_device_budget(int(0.3 * tv.all_resident_bytes()))
    base = _base(X)
    _force_all_synced(tv, Q)
    cold = sorted(tv.cold_clusters())
    assert cold
    c = cold[0]
    off = int(tv._cold.entries[c]["off"]) * tv._cold._row_bytes() + 3
    store_faults.flip_byte(tv._cold.payload_path, off)
    tv._cold._verified = set()          # drop the first-touch cache
    with pytest.warns(UserWarning, match="healing from the spill"):
        ids, d = tv.search_device_batched(Q, k=10, n_probe=8,
                                          use_pallas=False)
    bi, bd = base.search_device_batched(Q, k=10, n_probe=8,
                                        use_pallas=False)
    np.testing.assert_array_equal(ids, np.asarray(bi))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(bd))
    assert tv.stats.corrupt_reads >= 1
    assert c not in tv._quarantined
    assert all(r["ok"] for r in scrub_cold_pack(tv.storage_dir))


def test_cold_and_spill_both_corrupt_quarantines_and_widens(tmp_path, data):
    X, Q = data
    tv = _tiered(X, tmp_path / "t")
    tv.set_device_budget(int(0.3 * tv.all_resident_bytes()))
    _force_all_synced(tv, Q)
    cold = sorted(tv.cold_clusters())
    c = cold[0]
    off = int(tv._cold.entries[c]["off"]) * tv._cold._row_bytes() + 3
    store_faults.flip_byte(tv._cold.payload_path, off)
    tv._cold._verified = set()
    store_faults.flip_byte(
        os.path.join(tv.storage_dir, f"cluster_{c:05d}.bin"), 40)
    tv._cache.pop(c, None)
    tv._pending_graphs.pop(c, None)
    with pytest.warns(UserWarning):
        ids, _ = tv.search_device_batched(Q, k=10, n_probe=4,
                                          use_pallas=False)
    assert c in tv._quarantined
    assert (ids >= 0).all()             # probe widening kept k results
    _no_leaks(tv)


# -------------------------------------------------- memory accounting

def test_ram_bytes_counts_cache_and_mirrors(tmp_path, data):
    """Satellite 1: the LRU cluster cache and device mirrors are part of
    ram_bytes now."""
    X, Q = data
    plain = _base(X)
    cached = _base(X, cache_clusters=4)
    r0 = cached.ram_bytes()
    for q in Q[:6]:
        cached.search(q, k=5, n_probe=4)
    assert len(cached._cache) > 0
    assert cached.ram_bytes() > r0
    # device mirrors count once the pack is built
    before = plain.ram_bytes()
    plain.search_device_batched(Q[:2], k=5, n_probe=4, use_pallas=False)
    assert plain.device_resident_bytes() > 0
    assert plain.ram_bytes() >= before + plain.device_resident_bytes()


def test_tiered_ram_bytes_counts_cold_manifest(tmp_path, data):
    X, Q = data
    tv = _tiered(X, tmp_path / "t")
    tv.set_device_budget(int(0.3 * tv.all_resident_bytes()))
    _force_all_synced(tv, Q)
    assert tv.cold_clusters()
    ids_bytes = sum(e["ids"].nbytes
                    for e in tv._cold.entries.values())
    assert ids_bytes > 0
    assert tv.ram_bytes() > ids_bytes


def test_window_index_resident_bytes_and_dma_counters():
    docs = [f"sentence {i} one. sentence {i} two. sentence {i} three."
            for i in range(6)]
    emb = HashEmbedder(dim=32)
    wi = WindowIndex(emb, SCRConfig(use_pallas=False)).build(docs)
    before = wi.resident_bytes()
    assert before >= wi.ram_bytes()
    queries = ["sentence 1 one", "sentence 2 two"]
    doc_ids = [[0, 1, 2], [3, 4]]
    apply_scr_batch(queries, doc_ids, wi, emb, use_pallas=False)
    s = wi.stats
    assert s.select_calls == 1
    assert s.select_queries == 2
    assert s.blocks_dma == 5            # five non-padded (q, doc) pairs
    assert s.last_query_dma_blocks == 2.5
    # the device mirror built for scr_select now counts toward residency
    after = wi.resident_bytes()
    assert after > before
    assert s.resident_bytes == after


# ----------------------------------------------------------- planner

def test_tier_manager_hysteresis_blocks_thrash():
    tm = TierManager(4, alpha=0.3, hysteresis=1.25)
    hot = {0, 1}
    tm.record(np.array([[0, 1], [0, 1]]))     # hot clusters stay warm
    tm.record(np.array([[2]]))                # 2 warms up but not 1.25x
    promote, demote = tm.plan(hot, budget_rows=2, blocked=set())
    assert not promote and not demote
    for _ in range(6):
        tm.record(np.array([[2, 2, 2]]))      # now clearly hotter
    promote, demote = tm.plan(hot, budget_rows=2, blocked=set())
    assert 2 in promote and len(demote) == 1


def test_cold_pack_roundtrip_and_compaction(tmp_path):
    rng = np.random.default_rng(4)
    cp = ColdPack(str(tmp_path), dim=8)
    a = rng.normal(size=(5, 8)).astype(np.float32)
    b = rng.normal(size=(3, 8)).astype(np.float32)
    cp.put(0, np.arange(5), a)
    cp.put(1, np.arange(10, 13), b)
    cp.put(0, np.arange(5), a * 2)            # supersedes: dead span
    assert cp.file_bytes() > cp.live_rows() * cp._row_bytes()
    ids0, v0 = cp.get(0)
    np.testing.assert_array_equal(v0, a * 2)
    cp.compact()
    assert cp.file_bytes() == cp.live_rows() * cp._row_bytes()
    ids1, v1 = cp.get(1)
    np.testing.assert_array_equal(v1, b)
    np.testing.assert_array_equal(ids1, np.arange(10, 13))
    assert all(r["ok"] for r in scrub_cold_pack(str(tmp_path)))
