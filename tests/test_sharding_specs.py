"""Dist-layer spec helpers on a 1-device CPU environment: the logical->
physical mapping, shape pruning, no-mesh degradation, and RestartManager
surviving a simulated process crash (fresh manager instance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import checkpoint as ckpt
from repro.dist.fault import RestartManager
from repro.dist.sharding import (axis_size, fsdp_spans_pods, get_mesh,
                                 logical_to_spec, set_fsdp_spans_pods,
                                 shard, sharding_for,
                                 spec_tree_to_shardings, use_mesh)


class FakeMesh:
    """Shape-only stand-in so the mapping logic is testable for mesh
    geometries (4x2, multi-pod) that a 1-CPU host cannot instantiate."""

    def __init__(self, **shape):
        self._shape = dict(shape)

    @property
    def shape(self):
        return dict(self._shape)

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def size(self):
        n = 1
        for s in self._shape.values():
            n *= s
        return n


def real_mesh_1x1():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))


# ------------------------------------------------------------ mesh context


def test_no_mesh_is_default_and_nesting_restores():
    assert get_mesh() is None
    m1, m2 = FakeMesh(data=2), FakeMesh(data=4)
    with use_mesh(m1):
        assert get_mesh() is m1
        with use_mesh(m2):
            assert get_mesh() is m2
        assert get_mesh() is m1
    assert get_mesh() is None


def test_shard_is_identity_without_mesh():
    x = jnp.arange(6.0).reshape(2, 3)
    assert shard(x, "batch", "tp") is x
    assert shard(x) is x


def test_shard_is_identity_on_single_device_mesh():
    x = jnp.arange(8.0).reshape(2, 4)
    with use_mesh(real_mesh_1x1()):
        assert shard(x, "batch", "tp") is x


# ------------------------------------------------------- logical -> spec


def test_axis_size_off_mesh_and_on_mesh():
    assert axis_size(None, "tp") == 1
    m = FakeMesh(data=4, model=2)
    assert axis_size(m, "tp") == 2
    assert axis_size(m, "fsdp") == 4
    assert axis_size(m, "batch") == 4          # no pod axis on this mesh
    assert axis_size(FakeMesh(pod=2, data=4, model=2), "batch") == 8
    assert axis_size(m, None) == 1


def test_logical_to_spec_basic_mapping():
    m = FakeMesh(data=4, model=2)
    assert logical_to_spec(m, ("batch", None, "tp")) == \
        P("data", None, "model")
    assert logical_to_spec(m, ("fsdp", "tp")) == P("data", "model")
    assert logical_to_spec(m, ("expert", "fsdp", None)) == \
        P("model", "data", None)


def test_logical_to_spec_fsdp_spans_pods_toggle():
    m = FakeMesh(pod=2, data=4, model=2)
    try:
        assert logical_to_spec(m, ("fsdp",)) == P("data")
        set_fsdp_spans_pods(True)
        assert fsdp_spans_pods()
        assert logical_to_spec(m, ("fsdp",)) == P(("pod", "data"))
    finally:
        set_fsdp_spans_pods(False)
    assert logical_to_spec(m, ("batch",)) == P(("pod", "data"))


def test_logical_to_spec_prunes_indivisible_dims():
    m = FakeMesh(data=4, model=2)
    # 6 % 4 != 0 and 5 % 2 != 0 -> fully replicated
    assert logical_to_spec(m, ("batch", "tp"), shape=(6, 5)) == P(None, None)
    assert logical_to_spec(m, ("batch", "tp"), shape=(8, 4)) == \
        P("data", "model")
    # multi-axis entry keeps the divisible prefix: 2 % pod(2) == 0 but
    # 2 % (pod*data)=8 != 0 -> shard over pod only
    mp = FakeMesh(pod=2, data=4, model=2)
    assert logical_to_spec(mp, ("batch",), shape=(2,)) == P("pod")


def test_logical_to_spec_never_reuses_a_mesh_axis():
    m = FakeMesh(data=4, model=2)
    # "tp" and "expert" both map to "model": second claim is dropped
    assert logical_to_spec(m, ("tp", "expert")) == P("model", None)


def test_unknown_logical_axis_raises():
    with pytest.raises(ValueError, match="unknown logical axis"):
        logical_to_spec(FakeMesh(data=2), ("bogus",))


# ------------------------------------------------- tree-level shardings


def test_spec_tree_to_shardings_round_trips_a_pytree():
    mesh = real_mesh_1x1()
    tree = {"params": {"w": jnp.arange(32.0).reshape(4, 8),
                       "b": jnp.ones((8,), jnp.bfloat16)},
            "step": jnp.int32(3)}
    specs = {"params": {"w": ("fsdp", "tp"), "b": ("tp",)}, "step": ()}
    sh = spec_tree_to_shardings(mesh, specs, tree)
    assert jax.tree.structure(sh) == jax.tree.structure(tree)
    assert all(isinstance(s, NamedSharding) for s in jax.tree.leaves(sh))
    placed = jax.tree.map(jax.device_put, tree, sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(placed)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_spec_shorter_or_longer_than_rank_is_padded():
    mesh = real_mesh_1x1()
    x = jnp.ones((2, 3, 4))
    s = sharding_for(mesh, "batch", shape=x.shape)       # rank-1 spec
    assert s.spec == P(*logical_to_spec(mesh, ("batch", None, None),
                                        shape=x.shape))
    s2 = sharding_for(mesh, "batch", None, "tp", None, None,
                      shape=(2, 3))                      # over-long spec
    assert len(s2.spec) <= 2


# ------------------------------------------------------- restart manager


def test_restart_manager_resumes_after_simulated_crash(tmp_path):
    state = {"w": jnp.arange(4.0), "n": jnp.int32(7)}
    rm = RestartManager(str(tmp_path), interval=3)
    rm.on_step(1, state)                     # below interval: no save
    assert ckpt.latest_step(str(tmp_path)) is None
    rm.on_step(3, state)
    # "crash": the manager object is lost; a fresh process builds a new one
    rm2 = RestartManager(str(tmp_path), interval=3)
    restored, start = rm2.maybe_restore(jax.tree.map(jnp.zeros_like, state))
    assert start == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(4.0))
    assert restored["n"].dtype == jnp.int32


def test_restart_manager_async_save_commits_on_flush(tmp_path):
    rm = RestartManager(str(tmp_path), interval=2, async_save=True)
    rm.on_step(2, {"w": jnp.ones((3,))})
    rm.flush()
    assert ckpt.latest_step(str(tmp_path)) == 2
