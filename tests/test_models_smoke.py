"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs;
plus prefill->decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PAPER_IDS, get_reduced
from repro.models import model

SMOKE_S = {"qwen2_vl_2b": 320}  # vision needs S > N_IMG


@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_IDS)
def test_train_step_shapes_and_no_nans(arch):
    cfg = get_reduced(arch)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    S = SMOKE_S.get(arch, 64)
    batch = model.make_sample_batch(cfg, 2, S)
    loss, metrics = model.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    # one SGD-flavoured step must change the loss
    grads = jax.grad(lambda p: model.loss_fn(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill(t[:-1]) must reproduce the full forward's
    last-position logits — the KV-cache/state correctness contract."""
    cfg = get_reduced(arch)
    if cfg.causal is False:
        pytest.skip("encoder-only")
    params = model.init_params(cfg, jax.random.PRNGKey(1))
    S = SMOKE_S.get(arch, 48)
    batch = model.make_sample_batch(cfg, 2, S)
    pb = {k: v for k, v in batch.items() if k != "labels"}
    # full forward logits at the last position
    if cfg.family == "moe":
        from repro.models import moe
        from repro.models.common import cast_params
        full, _ = moe.forward_logits(
            cfg, cast_params(params, jnp.bfloat16), pb)
    elif cfg.family == "encdec":
        from repro.models import encdec
        from repro.models.common import cast_params
        full = encdec.forward_logits(cfg, cast_params(params, jnp.bfloat16),
                                     pb)
    else:
        from repro.models.common import cast_params
        full = model.family(cfg).forward_logits(
            cfg, cast_params(params, jnp.bfloat16), pb)
    full_last = np.asarray(full[:, -1], np.float32)

    # prefill on the prefix, decode the final token
    if cfg.family == "encdec":
        toks = pb["dec_tokens"]
        prefix = dict(pb)
        prefix["dec_tokens"] = toks[:, :-1]
        logits, cache = model.prefill(cfg, params, prefix)
        dec_pos = jnp.int32(toks.shape[1] - 1)
        step_tok = toks[:, -1:]
    else:
        toks = pb["tokens"]
        prefix = dict(pb)
        prefix["tokens"] = toks[:, :-1]
        logits, cache = model.prefill(cfg, params, prefix)
        dec_pos = jnp.int32(toks.shape[1] - 1)
        step_tok = toks[:, -1:]
    if cfg.family == "mamba2":
        dec_pos = jnp.int32(0)
    # grow cache by one slot for kv families
    def grow(x):
        if x.ndim == 5:
            z = jnp.zeros(x.shape[:2] + (1,) + x.shape[3:], x.dtype)
            return jnp.concatenate([x, z], axis=2)
        return x
    if cfg.family in ("dense", "moe", "encdec") and cfg.sliding_window is None:
        cache = {k: (grow(v) if k in ("k", "v") else v)
                 for k, v in cache.items()}
    out, _ = model.decode_step(cfg, params, cache, step_tok, dec_pos)
    np.testing.assert_allclose(np.asarray(out, np.float32), full_last,
                               rtol=0.12, atol=0.12)


def test_int8_kv_cache_decode_close_to_bf16():
    """kv_quant decode logits stay close to the bf16-cache path."""
    import dataclasses
    cfg = get_reduced("qwen2_72b")
    cfg8 = dataclasses.replace(cfg, kv_quant=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = model.make_sample_batch(cfg, 2, 48)
    pb = {"tokens": batch["tokens"][:, :-1]}
    tok = batch["tokens"][:, -1:]

    def run(c):
        logits, cache = model.prefill(c, params, pb)
        def grow(x):
            z = jnp.zeros(x.shape[:2] + (1,) + x.shape[3:], x.dtype)
            return jnp.concatenate([x, z], axis=2)
        cache = {k: grow(v) for k, v in cache.items()}
        out, _ = model.decode_step(c, params, cache, tok, jnp.int32(47))
        return np.asarray(out, np.float32)

    o16, o8 = run(cfg), run(cfg8)
    # int8 KV noise is small relative to logit scale
    denom = np.maximum(np.abs(o16).max(), 1.0)
    assert np.max(np.abs(o16 - o8)) / denom < 0.08
    # top-1 agreement
    assert (o16.argmax(-1) == o8.argmax(-1)).mean() >= 0.5


@pytest.mark.parametrize("arch", ["h2o_danube_1_8b", "mamba2_780m",
                                  "recurrentgemma_9b"])
def test_long_context_state_is_bounded(arch):
    """long_500k eligibility: decode cache size must not scale with
    sequence length (ring buffer / recurrent state)."""
    cfg = get_reduced(arch)
    c1 = jax.eval_shape(lambda: model.init_cache(cfg, 1, 1024))
    c2 = jax.eval_shape(lambda: model.init_cache(cfg, 1, 65536))
    b1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1))
    b2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2))
    assert b2 <= b1 * 1.01  # bounded by window/state, not seq len


def test_vocab_padding_is_harmless():
    cfg = get_reduced("granite_moe_1b_a400m")
    assert cfg.vocab_padded >= cfg.vocab_size
    assert cfg.vocab_padded % 256 == 0


def test_gte_encode_unit_norm():
    cfg = get_reduced("gte_small")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.arange(32).reshape(2, 16) % cfg.vocab_size
    out = model.encode(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(np.linalg.norm(np.asarray(out), axis=-1),
                               1.0, rtol=1e-4)
