"""Distributed correctness on a small multi-device mesh (subprocess with 8
forced host devices so the main test process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT_SHARDED_RETRIEVAL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.distributed import (make_sharded_retrieval,
                                        reference_retrieval)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    NC, CAP, d, B, k, P = 16, 32, 24, 4, 5, 6
    data = rng.normal(size=(NC, CAP, d)).astype(np.float32)
    lens = rng.integers(8, CAP + 1, NC).astype(np.int32)
    for c in range(NC):
        data[c, lens[c]:] = 0
    sid = (np.arange(NC * CAP).reshape(NC, CAP)).astype(np.int32)
    cent = data[:, 0, :].copy()
    q = rng.normal(size=(B, d)).astype(np.float32)
    ret = make_sharded_retrieval(mesh, k=k, n_probe=P)
    dists, ids = jax.jit(ret)(q, cent, data, lens, sid)
    rd, ri = reference_retrieval(q, cent, data, lens, sid, k=k, n_probe=P)
    np.testing.assert_allclose(np.asarray(dists), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(ids) == ri).all(), (ids, ri)
    print("SHARDED-RETRIEVAL-OK")
""")

SCRIPT_TRAIN_PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import RunConfig, ShapeConfig, TrainConfig
    from repro.configs import get_reduced
    from repro.dist.sharding import use_mesh
    from repro.models import model
    from repro.train import trainer
    cfg = get_reduced("h2o_danube_1_8b")
    shape = ShapeConfig("t", 32, 8, "train")
    run = RunConfig(model=cfg, shape=shape,
                    train=TrainConfig(grad_clip=0.0, warmup_steps=0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(4, 100, (8, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(4, 100, (8, 32)), jnp.int32)}
    params, opt_state = trainer.make_states(run, key=jax.random.PRNGKey(0))
    # single-device result
    s1, _, _ = trainer.make_train_step(run, microbatches=1, seq_sp=False)
    p_ref, _, m_ref = s1(params, opt_state, batch)
    # sharded result on a 4x2 mesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with use_mesh(mesh):
        s2, _, _ = trainer.make_train_step(run, microbatches=1)
        psh, osh, bsh = trainer.state_shardings(run, mesh)
        jit2 = jax.jit(s2, in_shardings=(psh, osh, bsh),
                       out_shardings=(psh, osh, None))
        p2, _, m2 = jit2(params, opt_state, batch)
    assert abs(float(m_ref["loss"]) - float(m2["loss"])) < 5e-3, \\
        (float(m_ref["loss"]), float(m2["loss"]))
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p_ref, p2)
    worst = max(jax.tree.leaves(d))
    assert worst < 5e-2, worst
    print("TRAIN-PARITY-OK", float(m2["loss"]))
""")

SCRIPT_MOE_PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_reduced
    from repro.dist.sharding import use_mesh
    from repro.models import model
    cfg = get_reduced("granite_moe_1b_a400m")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(4, 100, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(4, 100, (4, 32)), jnp.int32)}
    l1, _ = model.loss_fn(cfg, params, batch)   # local (no mesh) MoE path
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    with use_mesh(mesh):
        l2, _ = jax.jit(lambda p, b: model.loss_fn(cfg, p, b))(params, batch)
    # shard_map EP with capacity drop may differ slightly from local path
    assert abs(float(l1) - float(l2)) < 0.05, (float(l1), float(l2))
    print("MOE-PARITY-OK", float(l1), float(l2))
""")


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=560, cwd=".")
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-3000:]
    return p.stdout


@pytest.mark.slow
def test_sharded_retrieval_matches_reference():
    assert "SHARDED-RETRIEVAL-OK" in _run(SCRIPT_SHARDED_RETRIEVAL)


@pytest.mark.slow
def test_train_step_parity_single_vs_mesh():
    assert "TRAIN-PARITY-OK" in _run(SCRIPT_TRAIN_PARITY)


@pytest.mark.slow
def test_moe_shard_map_parity():
    assert "MOE-PARITY-OK" in _run(SCRIPT_MOE_PARITY)
