"""Elastic scaling: a checkpoint written under one mesh restores onto a
different mesh (lost/added hosts) with bit-identical values and working
training — the reshard_restore path of dist/fault.py."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import RunConfig, ShapeConfig, TrainConfig
    from repro.configs import get_reduced
    from repro.dist import checkpoint as ckpt
    from repro.dist.fault import reshard_restore
    from repro.dist.sharding import use_mesh, spec_tree_to_shardings
    from repro.models import model
    from repro.train import trainer, optimizer as opt

    tmp = os.environ["ELASTIC_TMP"]
    cfg = get_reduced("h2o_danube_1_8b")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                    train=TrainConfig(warmup_steps=0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(4, 100, (8, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(4, 100, (8, 32)), jnp.int32)}

    # ---- phase 1: train 2 steps on a 4x2 mesh, checkpoint
    mesh1 = jax.make_mesh((4, 2), ("data", "model"))
    with use_mesh(mesh1):
        params, opt_state = trainer.make_states(run, key=jax.random.PRNGKey(0))
        step, _, _ = trainer.make_train_step(run, microbatches=1)
        psh, osh, bsh = trainer.state_shardings(run, mesh1)
        jstep = jax.jit(step, in_shardings=(psh, osh, bsh),
                        out_shardings=(psh, osh, None))
        for _ in range(2):
            params, opt_state, m1 = jstep(params, opt_state, batch)
        ckpt.save(tmp, 2, (params, opt_state))
        ref_loss = float(m1["loss"])

    # ---- phase 2: "lose half the cluster": restore onto a 2x2 mesh
    mesh2 = jax.make_mesh((2, 2), ("data", "model"),
                          devices=jax.devices()[:4])
    with use_mesh(mesh2):
        like = trainer.make_states(run, abstract=True)
        pspecs = model.param_specs(cfg)
        ospecs = opt.opt_state_specs(pspecs, "float32")
        (params2, opt2), start = reshard_restore(tmp, like, mesh2,
                                                 (pspecs, ospecs))
        assert start == 3, start
        # values identical to the mesh-1 state
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and training continues on the smaller mesh
        step2, _, _ = trainer.make_train_step(run, microbatches=1)
        psh2, osh2, bsh2 = trainer.state_shardings(run, mesh2)
        jstep2 = jax.jit(step2, in_shardings=(psh2, osh2, bsh2),
                         out_shardings=(psh2, osh2, None))
        params2, opt2, m2 = jstep2(params2, opt2, batch)
        assert np.isfinite(float(m2["loss"]))
    print("ELASTIC-OK", ref_loss, float(m2["loss"]))
""")


@pytest.mark.slow
def test_elastic_reshard_across_meshes(tmp_path):
    env = dict(os.environ, PYTHONPATH="src", ELASTIC_TMP=str(tmp_path))
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=".",
                       capture_output=True, text=True, timeout=560)
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-3000:]
    assert "ELASTIC-OK" in p.stdout
